"""Continuous-batching serving off a loaded quantized artifact.

    PYTHONPATH=src python examples/continuous_serve.py

End-to-end on CPU in under a minute: quantize a reduced model through the
front door (``repro.api``), save + reload the packed artifact, then serve
a mixed-length request trace through the continuous scheduler —
``submit()`` with a streaming token callback, per-slot stop + refill over
the block-paged KV pool, and the queue-wait / TTFT / decode-slot
utilisation metrics the scheduler keeps.  Then replays a shared
system-prompt workload with ``ServeConfig(prefix_cache=True)`` — every
request after the first maps the prompt's cached KV blocks instead of
re-prefilling them (watch ``prefix_hit_rate`` and the saved prefill
tokens), bit-identical to the uncached run.  Next, self-drafted
speculative decoding: ``api.derive_draft`` re-rounds the *same* packed
artifact under a harsher weight-only policy (no second checkpoint), and
``ServeConfig(spec_decode=True)`` drafts k tokens per verify call over
the shared paged pool — fewer target-model invocations, token-identical
output, acceptance rate in the metrics.  A fault-replay section then
poisons one request's logits with a deterministic ``api.FaultPlan`` and
shows request isolation: the victim fails with status + error, the pool
reconciles, and every surviving request's tokens are bit-identical to
the clean run.  Finishes by showing the
``generate()`` compatibility wrapper produces the same greedy tokens as
the static fixed-batch loop it replaced, and dumps the recorded
observability artifacts — a Chrome trace of every request's
queue/prefill/decode lifecycle (open in ``chrome://tracing`` or
Perfetto) plus the Prometheus metrics — to ``results/``.
"""
import os
import tempfile

import numpy as np

import jax
import jax.numpy as jnp

from repro import api
from repro.models.registry import get_arch
from repro.serve.scheduler import synthetic_trace


def main():
    # 1. Quantize -> save -> load (no re-quantization on the serve path) --
    arch = get_arch("smollm-135m", reduced=True)
    cfg = arch.config
    params = arch.init(jax.random.PRNGKey(0), jnp.float32)
    qm = api.quantize(arch, params,
                      api.PTQConfig(r1_kind="GSR", wakv="W4A8", group=32))
    with tempfile.TemporaryDirectory() as d:
        qm.save(d, shards=2)  # one shard per host on a cluster
        loaded = api.load_quantized(d)
        print(f"artifact reloaded: {loaded.config.name}, "
              f"{loaded.packed_bytes() / 2**20:.2f} MiB packed, 2 shards")

        # 2. A continuous engine: 2 decode slots, 8-token KV blocks, with
        #    observability on (spans + metrics; off by default) ----------
        eng = loaded.serve(api.ServeConfig(max_seq=48, batch_slots=2,
                                           block_tokens=8,
                                           obs=api.ObsConfig(enabled=True)))

        # 3. Stream a mixed-length trace through submit/step/drain --------
        def stream(req, tok, done):
            flag = " <- finished" if done else ""
            print(f"  r{req.rid}: token {len(req.tokens):2d} = {int(tok)}{flag}")

        trace = synthetic_trace(cfg, 5, seed=3, prompt_len=8,
                                max_new_low=2, max_new_high=8)
        for r in trace:
            r.on_token = stream if r is trace[0] else None
            eng.scheduler.submit(r)
        while eng.step():  # tick-by-tick: admit, batched decode, refill
            pass
        m = eng.scheduler.metrics()["aggregate"]
        print(f"drained {m['n_requests']} requests / "
              f"{m['tokens_generated']} tokens; decode-slot utilisation "
              f"{m['slot_utilisation']:.2f}, mean TTFT "
              f"{m['mean_ttft_s'] * 1e3:.1f} ms, mean queue wait "
              f"{m['mean_queue_wait_s'] * 1e3:.1f} ms")

        # 4. Shared system prompt + prefix cache: prefill once, share the
        #    cached KV blocks with every later request (token-identical) --
        rng = np.random.default_rng(7)
        system_prompt = rng.integers(0, cfg.vocab, size=(24,)).astype(np.int32)
        questions = [rng.integers(0, cfg.vocab, size=(6,)).astype(np.int32)
                     for _ in range(4)]
        replies = {}
        for cached in (False, True):
            peng = loaded.serve(api.ServeConfig(max_seq=64, batch_slots=2,
                                                block_tokens=8,
                                                prefix_cache=cached))
            reqs = [peng.submit(np.concatenate([system_prompt, q]), 5)
                    for q in questions]
            peng.drain()
            replies[cached] = [r.token_array() for r in reqs]
            pm = peng.scheduler.metrics()["aggregate"]
            if cached:
                print(f"prefix cache on:  hit rate {pm['prefix_hit_rate']:.2f}"
                      f" ({pm['prefill_tokens_saved']} prompt tokens saved, "
                      f"{pm['blocks_shared']} blocks shared, "
                      f"{pm['cow_copies']} cow copies)")
            else:
                print(f"prefix cache off: {pm['prefill_tokens_computed']} "
                      f"prompt tokens prefilled")
        assert all(np.array_equal(a, b) for a, b in
                   zip(replies[False], replies[True]))
        print("shared-prefix replies identical with the cache on")

        # 5. Self-drafted speculative decoding: the draft is this same
        #    artifact re-rounded harsher (shared rotations/KV codec/pool);
        #    each decode step verifies k drafted tokens in one chunked
        #    call, so the trace finishes in fewer target invocations ----
        draft = api.derive_draft(loaded, "draft-w3-rtn")
        print(f"draft derived from the artifact: {draft.policy.name} "
              f"({draft.packed_bytes() / 2**20:.2f} MiB packed)")
        runs = {}
        for k in (0, 4):  # 0 = plain one-token-per-step decode
            seng = loaded.serve(api.ServeConfig(
                max_seq=48, batch_slots=2, block_tokens=8,
                spec_decode=k > 0, draft_k=max(k, 1)),
                draft=draft if k else None)
            rs = [seng.scheduler.submit(r)
                  for r in synthetic_trace(cfg, 5, seed=3, prompt_len=8,
                                           max_new_low=2, max_new_high=8)]
            seng.drain()
            sm = seng.scheduler.metrics()["aggregate"]
            runs[k] = ([r.token_array() for r in rs], sm["decode_steps"])
            if k:
                print(f"spec decode k={k}: acceptance "
                      f"{sm['spec_acceptance_rate']:.2f} "
                      f"({sm['spec_accepted_tokens']}/"
                      f"{sm['spec_draft_tokens']} draft tokens), "
                      f"{sm['decode_steps']} verify steps vs "
                      f"{runs[0][1]} baseline decode steps")
        assert all(np.array_equal(a, b)
                   for a, b in zip(runs[0][0], runs[4][0]))
        print("speculative replies identical to plain greedy decode")

        # 6. Fault replay: the same trace with one request's logits
        #    poisoned mid-stream (a deterministic FaultPlan).  The
        #    poisoned request fails cleanly (status + error, blocks
        #    released) while every survivor's tokens are bit-identical
        #    to the clean run — isolation, not crash-and-restart ------
        def fault_run(plan):
            feng = loaded.serve(api.ServeConfig(
                max_seq=48, batch_slots=2, block_tokens=8, faults=plan,
                health_every_syncs=4))
            rs = [feng.scheduler.submit(r)
                  for r in synthetic_trace(cfg, 5, seed=3, prompt_len=8,
                                           max_new_low=2, max_new_high=8)]
            feng.drain()
            return feng, rs

        _, clean_rs = fault_run(None)
        feng, fault_rs = fault_run(api.FaultPlan(nan_logits=[(1, 2)]))
        victim = fault_rs[1]
        print(f"injected NaN: r{victim.rid} {victim.status} after "
              f"{len(victim.tokens)} tokens ({victim.error})")
        assert victim.status == "failed" and len(victim.tokens) == 2
        assert all(np.array_equal(c.token_array(), f.token_array())
                   for c, f in zip(clean_rs, fault_rs) if f.status == "done")
        feng.pool.check_invariants()  # resources reconciled after the loss
        h = feng.health()
        print(f"survivors bit-identical to the clean run; health: "
              f"{h['status']}, {h['requests_done']} done / "
              f"{h['requests_failed']} failed, pool invariants "
              f"{'ok' if h['pool']['invariants_ok'] else 'VIOLATED'}")

        # 7. generate() wraps the same scheduler; static loop is the oracle
        prompts = np.asarray(
            jax.random.randint(jax.random.PRNGKey(1), (3, 8), 0, cfg.vocab))
        cont = eng.generate(prompts, max_new_tokens=6)
        static = loaded.serve(
            api.ServeConfig(max_seq=48, batch_slots=3)
        ).generate_static(prompts, max_new_tokens=6)
        assert np.array_equal(cont["tokens"], static["tokens"])
        print("continuous generate() == static generate_static():",
              cont["tokens"].shape, "tokens identical")

        # 8. Dump what the traced engine observed: one span tree per
        #    request (queue -> prefill -> decode, token instants) and the
        #    metrics registry (TTFT/queue-wait histograms, counters) -----
        from repro.obs import validate_chrome_trace

        os.makedirs("results", exist_ok=True)
        trace_path = eng.obs.export_trace("results/example_trace.json")
        metrics_path = eng.obs.export_metrics("results/example_metrics.prom")
        stats = validate_chrome_trace(eng.obs.tracer.to_chrome())
        print(f"trace: {trace_path} ({stats['spans']} spans over "
              f"{stats['requests']} request lanes) -> chrome://tracing; "
              f"metrics: {metrics_path}")


if __name__ == "__main__":
    main()
