"""Quickstart: GSR rotation -> one-call quantization -> save -> re-serve.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's pipeline end-to-end on a reduced llama-family model in
under a minute on CPU: construct the rotation kinds, verify fp
invariance, compare W2 quant error per rotation, then the front-door API
(``repro.api``): quantize once into a packed ``QuantizedModel`` artifact,
save it, load it back bit-exact, and serve greedy generations from the
loaded artifact through both weight backends.
"""
import tempfile

import numpy as np

import jax
import jax.numpy as jnp

from repro import api
from repro.core.hadamard import hadamard, sequency_of_rows, walsh
from repro.core.rotation import make_rotation
from repro.models.registry import get_arch


def main():
    # 1. Sequency: the paper's core construction ---------------------------
    print("H8 row sequencies (natural order): ", sequency_of_rows(hadamard(8)))
    print("Walsh8 row sequencies (ascending): ", sequency_of_rows(walsh(8)))

    # 2. A model + batch ----------------------------------------------------
    arch = get_arch("smollm-135m", reduced=True)
    cfg = arch.config
    params = arch.init(jax.random.PRNGKey(0), jnp.float32)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    batch = {"tokens": tokens}
    base = arch.forward(params, batch)

    # 3. Rotation fusion is exact in fp ------------------------------------
    from repro.core.fuse import fuse_rotations

    r1 = make_rotation("GSR", cfg.d_model, group=32)
    fused = fuse_rotations(cfg, params, r1)
    rot = arch.forward(fused, batch)
    print(f"fp invariance |base-rotated|_max = "
          f"{float(jnp.abs(base - rot).max()):.2e}")

    # 4. W2 PTQ with each rotation kind (packed artifacts) -----------------
    print("\nW2A16 (RTN) logit error vs fp, per rotation kind:")
    for kind in ("I", "GH", "GW", "LH", "GSR"):
        ptq = api.PTQConfig(r1_kind=kind, wakv="W2A16", method="rtn", group=32)
        qm = api.quantize(arch, params, ptq)
        ql = arch.forward(qm.params, batch, qm.spec)  # packed execution
        err = float(jnp.linalg.norm(ql - base) / jnp.linalg.norm(base))
        print(f"  R1={kind:4s} relative logit error = {err:.4f} "
              f"({qm.packed_bytes()/2**20:.2f} MiB packed)")

    # 5. The front door: quantize once, save, re-serve ---------------------
    print("\nquantize -> save -> load -> serve (no re-quantization):")
    qm = api.quantize(arch, params,
                      api.PTQConfig(r1_kind="GSR", wakv="W4A8", method="rtn",
                                    group=32))
    artifact_dir = tempfile.mkdtemp(prefix="gsr_artifact_")
    qm.save(artifact_dir)
    loaded = api.load_quantized(artifact_dir)
    print(f"  saved + loaded {artifact_dir}: R1={loaded.rotation['r1_kind']}, "
          f"{loaded.ptq.wakv}, {loaded.packed_bytes()/2**20:.2f} MiB packed")
    prompts = np.asarray(tokens[:, :16])
    for backend in ("reference", "pallas"):
        eng = loaded.serve(api.ServeConfig(max_seq=48, batch_slots=2),
                           backend=backend)
        out = eng.generate(prompts, max_new_tokens=8)
        print(f"  backend={backend:9s} tokens: {out['tokens'][0].tolist()}")
    print("\n(expect rotations to beat identity and both backends to agree; "
          "see benchmarks/ for the trained-model PPL tables)")


if __name__ == "__main__":
    main()
