"""Quickstart: build a GSR rotation, fuse it into a model, quantize, compare.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's pipeline end-to-end on a reduced llama-family model in
under a minute on CPU: construct the four rotation kinds, verify fp
invariance, W2-quantize with each, and print the quant-error ordering.
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core.hadamard import hadamard, sequency_of_rows, walsh
from repro.core.rotation import make_rotation
from repro.models.common import NOQUANT
from repro.models.registry import get_arch
from repro.quant.pipeline import PTQConfig, quantize_model


def main():
    # 1. Sequency: the paper's core construction ---------------------------
    print("H8 row sequencies (natural order): ", sequency_of_rows(hadamard(8)))
    print("Walsh8 row sequencies (ascending): ", sequency_of_rows(walsh(8)))

    # 2. A model + batch ----------------------------------------------------
    arch = get_arch("smollm-135m", reduced=True)
    cfg = arch.config
    params = arch.init(jax.random.PRNGKey(0), jnp.float32)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    batch = {"tokens": tokens}
    base = arch.forward(params, batch)

    # 3. Rotation fusion is exact in fp ------------------------------------
    from repro.core.fuse import fuse_rotations

    r1 = make_rotation("GSR", cfg.d_model, group=32)
    fused = fuse_rotations(cfg, params, r1)
    rot = arch.forward(fused, batch)
    print(f"fp invariance |base-rotated|_max = "
          f"{float(jnp.abs(base - rot).max()):.2e}")

    # 4. W2 PTQ with each rotation kind ------------------------------------
    print("\nW2A16 (RTN) logit error vs fp, per rotation kind:")
    for kind in ("I", "GH", "GW", "LH", "GSR"):
        ptq = PTQConfig(r1_kind=kind, wakv="W2A16", method="rtn", group=32)
        qp, spec = quantize_model(arch, params, ptq)
        ql = arch.forward(qp, batch, spec)
        err = float(jnp.linalg.norm(ql - base) / jnp.linalg.norm(base))
        print(f"  R1={kind:4s} relative logit error = {err:.4f}")
    print("\n(expect rotations to beat identity; see benchmarks/ for the "
          "trained-model PPL tables)")


if __name__ == "__main__":
    main()
