"""End-to-end driver: train, PTQ once into an artifact, re-serve it.

    PYTHONPATH=src python examples/quantize_pipeline.py [--steps 300]

1. trains smollm-135m (reduced widths for CPU; pass --full for the real
   config if you have the compute) for a few hundred steps with the
   fault-tolerant Trainer (checkpoints + resume);
2. PTQs the result through the front door (``repro.api.quantize``) with
   the paper's full recipe (GSR R1, GPTQ weights, MSE clipping, grouped
   W4A8) and the GH baseline, comparing held-out perplexity of the packed
   models;
3. saves the GSR artifact, loads it back (bit-exact, no re-quantization),
   and serves greedy generations from the *loaded* copy - the deploy
   path: quantize once, save, re-serve forever.
"""
import argparse

import numpy as np

import jax
import jax.numpy as jnp

from repro import api
from repro.data import SyntheticLM
from repro.data.synthetic import make_batch_for
from repro.models.common import NOQUANT
from repro.models.registry import get_arch
from repro.train.optimizer import OptConfig
from repro.train.train_step import make_eval_step
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--full", action="store_true", help="full 135M config")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_quickstart_ckpt")
    ap.add_argument("--artifact-dir", default="/tmp/repro_quickstart_artifact")
    ap.add_argument("--backend", default="reference",
                    choices=("reference", "pallas"))
    args = ap.parse_args()

    arch = get_arch("smollm-135m", reduced=not args.full)
    cfg = arch.config
    print(f"[1/3] training {cfg.name} ({cfg.param_count()[0]/1e6:.1f}M params) "
          f"for {args.steps} steps")
    opt = OptConfig(lr=1e-2, warmup_steps=20, total_steps=args.steps)
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_interval=100,
                         ckpt_dir=args.ckpt_dir, log_interval=50)
    trainer = Trainer(arch, opt, tcfg)
    data = SyntheticLM(cfg.vocab, args.seq, seed=1)

    def batches():
        step = trainer.step
        while True:
            yield make_batch_for(cfg, data, step, 0, args.batch)
            step += 1

    out = trainer.run(batches())
    params = out["state"]["params"]

    print("[2/3] PTQ via repro.api: GSR vs GH (W4A8, GPTQ, MSE clip, group 32)")
    ev = jax.jit(make_eval_step(arch, NOQUANT))
    held = {"tokens": jnp.asarray(data.batch(10_000, 0, 16))}
    base_nll = float(ev(params, held)["nll"])
    print(f"  fp16      ppl = {np.exp(base_nll):9.3f}")
    artifacts = {}
    for kind in ("GH", "GSR"):
        ptq = api.PTQConfig(r1_kind=kind, wakv="W4A8", method="gptq", group=32,
                            n_calib=4, calib_seq=args.seq)
        qm = api.quantize(arch, params, ptq)
        evq = jax.jit(make_eval_step(arch, qm.spec))
        nll = float(evq(qm.params, held)["nll"])  # packed execution
        artifacts[kind] = qm
        print(f"  {kind:4s} W4A8 ppl = {np.exp(nll):9.3f} "
              f"({qm.packed_bytes()/2**20:.2f} MiB packed)")

    print(f"[3/3] save -> load -> serve the GSR artifact ({args.artifact_dir})")
    artifacts["GSR"].save(args.artifact_dir)
    loaded = api.load_quantized(args.artifact_dir)
    eng = loaded.serve(
        api.ServeConfig(max_seq=args.seq + 24, batch_slots=4),
        backend=args.backend,
    )
    prompts = data.batch(20_000, 0, 3)[:, :16].astype(np.int32)
    gen = eng.generate(prompts, max_new_tokens=12)
    print(f"  served off the loaded artifact (backend={args.backend}); "
          "generated token ids:")
    for row in gen["tokens"]:
        print("   ", row.tolist())
    print(f"  re-serve any time: PYTHONPATH=src python -m repro.launch.serve "
          f"--artifact {args.artifact_dir}")


if __name__ == "__main__":
    main()
