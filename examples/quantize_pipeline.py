"""End-to-end driver: train, PTQ under declarative policies, re-serve.

    PYTHONPATH=src python examples/quantize_pipeline.py [--steps 300]

1. trains smollm-135m (reduced widths for CPU; pass --full for the real
   config if you have the compute) for a few hundred steps with the
   fault-tolerant Trainer (checkpoints + resume);
2. PTQs the result through the policy front door (``repro.api``):
   the flat-config baseline (``PTQConfig`` — lowers to a single-rule
   policy), the mixed-precision ``w2-sensitive-fp4`` preset (W2
   everywhere, sensitive down projections at W4 with a per-site GSR
   online rotation), and the composed-rotation ``gsr-over-spinquant``
   recipe (SpinQuant-lite learned R1 with a GSR post-rotation — the
   paper's "GSR over optimization-based methods" experiment), comparing
   held-out perplexity of the packed models;
3. saves the mixed-precision artifact (its resolved policy rides the
   manifest), loads it back (bit-exact, no re-quantization), and serves
   greedy generations from the *loaded* copy - the deploy path:
   quantize once, save, re-serve forever.

Custom recipes are plain data — e.g. GSR rotation with GPTQ attention
but cheap RTN experts, W2 except the first layer, and A8 activations
spent only on the R4-rotated down projections (``act_bits`` on a rule
overrides the policy-global activation default at the sites it
matches):

    policy = api.QuantPolicy(
        rules=(api.SiteRule(pattern="*", layers=(0, 0), bits=4, group=32),
               api.SiteRule(pattern="w[qkv]", bits=2, group=32,
                            method="gptq"),
               api.SiteRule(pattern="*down*", bits=2, group=32,
                            act_bits=8),  # per-site activation rule
               api.SiteRule(pattern="*", bits=2, group=32)),
        rotation=api.RotationPlan(r1=api.RotationSpec(kind="GSR", group=32)),
        act_bits=16,  # everywhere a rule doesn't say otherwise
    )
    qm = api.quantize(arch, params, policy)
"""
import argparse

import numpy as np

import jax
import jax.numpy as jnp

from repro import api
from repro.data import SyntheticLM
from repro.data.synthetic import make_batch_for
from repro.models.common import NOQUANT
from repro.models.registry import get_arch
from repro.train.optimizer import OptConfig
from repro.train.train_step import make_eval_step
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--full", action="store_true", help="full 135M config")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_quickstart_ckpt")
    ap.add_argument("--artifact-dir", default="/tmp/repro_quickstart_artifact")
    ap.add_argument("--backend", default="reference",
                    choices=("reference", "pallas"))
    args = ap.parse_args()

    arch = get_arch("smollm-135m", reduced=not args.full)
    cfg = arch.config
    print(f"[1/3] training {cfg.name} ({cfg.param_count()[0]/1e6:.1f}M params) "
          f"for {args.steps} steps")
    opt = OptConfig(lr=1e-2, warmup_steps=20, total_steps=args.steps)
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_interval=100,
                         ckpt_dir=args.ckpt_dir, log_interval=50)
    trainer = Trainer(arch, opt, tcfg)
    data = SyntheticLM(cfg.vocab, args.seq, seed=1)

    def batches():
        step = trainer.step
        while True:
            yield make_batch_for(cfg, data, step, 0, args.batch)
            step += 1

    out = trainer.run(batches())
    params = out["state"]["params"]

    print("[2/3] PTQ under three policies (flat W4A8 GPTQ baseline, "
          "mixed-precision, composed rotation)")
    ev = jax.jit(make_eval_step(arch, NOQUANT))
    held = {"tokens": jnp.asarray(data.batch(10_000, 0, 16))}
    base_nll = float(ev(params, held)["nll"])
    print(f"  fp16                     ppl = {np.exp(base_nll):9.3f}")

    recipes = {
        # the flat config is still one line - and is itself a policy
        "gsr-w4a8-gptq": api.PTQConfig(r1_kind="GSR", wakv="W4A8",
                                       method="gptq", group=32, n_calib=4,
                                       calib_seq=args.seq),
        # W2 everywhere except the sensitive down projections at W4
        # (per-site GSR online rotation) - unreachable from a flat config
        "w2-sensitive-fp4": api.get_policy("w2-sensitive-fp4"),
        # SpinQuant-lite learned R1 composed with a GSR post-rotation
        "gsr-over-spinquant": api.get_policy("gsr-over-spinquant"),
    }
    artifacts = {}
    for name, recipe in recipes.items():
        qm = api.quantize(arch, params, recipe)
        evq = jax.jit(make_eval_step(arch, qm.spec))
        nll = float(evq(qm.params, held)["nll"])  # packed execution
        artifacts[name] = qm
        print(f"  {name:24s} ppl = {np.exp(nll):9.3f} "
              f"({qm.packed_bytes()/2**20:.2f} MiB packed)")

    print(f"[3/3] save -> load -> serve the mixed-precision artifact "
          f"({args.artifact_dir})")
    artifacts["w2-sensitive-fp4"].save(args.artifact_dir)
    loaded = api.load_quantized(args.artifact_dir)
    print(f"  loaded: {loaded.policy.describe()}")
    eng = loaded.serve(
        api.ServeConfig(max_seq=args.seq + 24, batch_slots=4),
        backend=args.backend,
    )
    prompts = data.batch(20_000, 0, 3)[:, :16].astype(np.int32)
    gen = eng.generate(prompts, max_new_tokens=12)
    print(f"  served off the loaded artifact (backend={args.backend}); "
          "generated token ids:")
    for row in gen["tokens"]:
        print("   ", row.tolist())
    print(f"  re-serve any time: PYTHONPATH=src python -m repro.launch.serve "
          f"--artifact {args.artifact_dir}")


if __name__ == "__main__":
    main()
