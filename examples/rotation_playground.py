"""Rotation anatomy: visualize what GSR does to outliers vs GH/GW/LH.

    PYTHONPATH=src python examples/rotation_playground.py

Builds an activation matrix with massive outlier channels (the regime
rotation-based PTQ targets), applies each rotation kind, and prints
per-group dynamic-range statistics - the quantity group quantization
cares about.  Also demos the online kernels (FWHT vs grouped rotate).
"""
import numpy as np

import jax.numpy as jnp

from repro.core.rotation import apply_rotation, make_rotation
from repro.kernels import ops

DIM, GROUP, ROWS = 512, 64, 256


def group_range_stats(x: np.ndarray, group: int):
    """Mean per-group dynamic range (max-min within quantization groups)."""
    g = x.reshape(x.shape[0], x.shape[1] // group, group)
    rng = g.max(-1) - g.min(-1)
    return float(rng.mean()), float(rng.max())


def main():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(ROWS, DIM)).astype(np.float32)
    idx = rng.choice(DIM, size=6, replace=False)
    x[:, idx] *= 25.0  # outlier channels

    print(f"activation matrix {x.shape}, 6 outlier channels x25")
    print(f"{'kind':>6s} {'mean grp range':>15s} {'max grp range':>14s}")
    for kind in ("I", "GH", "GW", "LH", "GSR"):
        rot = make_rotation(kind, DIM, group=GROUP, seed=0)
        y = np.asarray(apply_rotation(jnp.asarray(x), rot))
        m, mx = group_range_stats(y, GROUP)
        print(f"{kind:>6s} {m:15.2f} {mx:14.2f}")

    print("\nonline rotation kernels (Pallas interpret mode):")
    y1 = np.asarray(ops.fwht(jnp.asarray(x)))
    rot = make_rotation("GSR", DIM, group=GROUP)
    y2 = np.asarray(ops.grouped_rotate(jnp.asarray(x),
                                       jnp.asarray(rot.matrix, jnp.float32)[None]))
    print(f"  fwht out norm          = {np.linalg.norm(y1):.2f} "
          f"(isometry: in={np.linalg.norm(x):.2f})")
    print(f"  grouped_rotate out norm = {np.linalg.norm(y2):.2f}")
    print("\nNote how local rotations (LH/GSR) keep outlier energy confined "
          "to its group\nwhile global kinds smear it across all groups "
          "(paper Fig. 2).")


if __name__ == "__main__":
    main()
